"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

All kernels execute in interpret mode on this CPU container (the kernel
body runs in Python) — the same code lowers to Mosaic on a real TPU.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import ref as attn_ref
from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.grouped_matmul import ops as gmm_ops, ref as gmm_ref
from repro.kernels.segment_softmax import ref as ss_ref
from repro.kernels.segment_softmax.segment_softmax import \
    segment_softmax_pallas
from repro.kernels.spmm import ops as spmm_ops, ref as spmm_ref
from repro.kernels.spmm.spmm import spmm_ell_pallas


# --------------------------------------------------------------------- spmm
@pytest.mark.parametrize("reduce", ["sum", "mean", "max", "min"])
@pytest.mark.parametrize("shape", [(8, 3, 16, 128), (16, 7, 50, 256),
                                   (24, 1, 10, 384)])
def test_spmm_ell_kernel_sweep(rng, reduce, shape):
    rows, k, n, f = shape
    ell = rng.integers(-1, n, (rows, k)).astype(np.int32)
    w = rng.standard_normal((rows, k)).astype(np.float32)
    x = rng.standard_normal((n, f)).astype(np.float32)
    use_w = None if reduce in ("max", "min") else jnp.asarray(w)
    a = spmm_ref.spmm_ell(jnp.asarray(ell), use_w, jnp.asarray(x),
                          reduce=reduce)
    b = spmm_ell_pallas(jnp.asarray(ell), use_w, jnp.asarray(x),
                        reduce=reduce, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_spmm_dtypes(rng, dtype):
    ell = rng.integers(-1, 20, (8, 4)).astype(np.int32)
    x = rng.standard_normal((20, 128)).astype(dtype)
    a = spmm_ref.spmm_ell(jnp.asarray(ell), None, jnp.asarray(x))
    b = spmm_ell_pallas(jnp.asarray(ell), None, jnp.asarray(x),
                        interpret=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-2,
                               atol=1e-2)


def test_csr_to_ell_roundtrip(rng):
    indptr = np.array([0, 2, 2, 5, 6])
    indices = np.array([1, 3, 0, 2, 4, 5])
    w = rng.standard_normal(6).astype(np.float32)
    x = rng.standard_normal((8, 128)).astype(np.float32)
    ell, ellw = spmm_ops.csr_to_ell(indptr, indices, w)
    a = spmm_ref.spmm_csr(jnp.asarray(indptr), jnp.asarray(indices),
                          jnp.asarray(x), jnp.asarray(w), num_rows=4)
    b = spmm_ops.spmm_ell(jnp.asarray(ell), jnp.asarray(ellw),
                          jnp.asarray(x), force_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b)[:4], rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("reduce", ["sum", "mean", "max", "min"])
def test_spmm_ell_grad_matches_oracle(rng, reduce):
    """The ops-level custom VJP: kernel-path gradients (features AND
    weights) == XLA-oracle gradients for every reduce mode."""
    rows, k, n, f = 16, 5, 23, 128
    ell = jnp.asarray(rng.integers(-1, n, (rows, k)).astype(np.int32))
    w = jnp.asarray(rng.standard_normal((rows, k)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))

    def loss(fn, x_, w_):
        out = fn(ell, w_, x_)
        return (out * jnp.sin(jnp.arange(out.size).reshape(out.shape))).sum()

    kernel = lambda e, w_, x_: spmm_ops.spmm_ell(
        e, w_, x_, reduce=reduce, force_pallas=True, interpret=True)
    oracle = lambda e, w_, x_: spmm_ref.spmm_ell(e, w_, x_, reduce=reduce)
    gk = jax.grad(lambda x_, w_: loss(kernel, x_, w_), argnums=(0, 1))(x, w)
    go = jax.grad(lambda x_, w_: loss(oracle, x_, w_), argnums=(0, 1))(x, w)
    for a, b in zip(gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_spmm_ell_grad_row_chunked(rng, monkeypatch):
    """The VJP covers the multi-launch (SMEM row-chunked) forward too."""
    monkeypatch.setattr(spmm_ops, "MAX_PREFETCH_ELEMS", 64)
    rows, k, n, f = 40, 5, 23, 128
    ell = jnp.asarray(rng.integers(-1, n, (rows, k)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    gk = jax.grad(lambda x_: spmm_ops.spmm_ell(
        ell, None, x_, force_pallas=True, interpret=True).sum())(x)
    go = jax.grad(lambda x_: spmm_ref.spmm_ell(ell, None, x_).sum())(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(go), rtol=1e-4,
                               atol=1e-4)


def test_raw_spmm_kernel_grad_raises_actionable(rng):
    """Differentiating the raw Pallas kernel must fail with a clear
    NotImplementedError naming the fallback env var — not an opaque
    'no differentiation rule for pallas_call' trace error."""
    ell = jnp.asarray(rng.integers(-1, 10, (8, 4)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal((10, 128)).astype(np.float32))
    with pytest.raises(NotImplementedError, match="REPRO_USE_PALLAS"):
        jax.grad(lambda x_: spmm_ell_pallas(ell, None, x_,
                                            interpret=True).sum())(x)


# ----------------------------------------------------------- grouped matmul
@pytest.mark.parametrize("g,k,n", [(4, 128, 128), (8, 256, 384),
                                   (3, 100, 72)])
def test_gmm_kernel_sweep(rng, g, k, n):
    sizes = rng.integers(0, 200, g).astype(np.int32)
    sizes[0] = max(sizes[0], 1)
    m = int(sizes.sum())
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = (rng.standard_normal((g, k, n)) * 0.05).astype(np.float32)
    a = gmm_ref.grouped_matmul(jnp.asarray(x), jnp.asarray(w),
                               jnp.asarray(sizes))
    b = gmm_ops.grouped_matmul(jnp.asarray(x), jnp.asarray(w),
                               jnp.asarray(sizes), force_pallas=True,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_gmm_xla_path_matches(rng):
    sizes = np.array([64, 0, 130], np.int32)
    x = rng.standard_normal((194, 64)).astype(np.float32)
    w = (rng.standard_normal((3, 64, 32)) * 0.1).astype(np.float32)
    a = gmm_ref.grouped_matmul_dense(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(sizes))
    b = gmm_ops.grouped_matmul(jnp.asarray(x), jnp.asarray(w),
                               jnp.asarray(sizes), force_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_gmm_grad_matches_oracle(rng):
    """The grouped-matmul custom VJP (two grouped GEMMs over the forward
    tile->group table) == oracle gradients, incl. an empty group."""
    sizes = np.array([40, 0, 130], np.int32)
    m = int(sizes.sum())
    x = jnp.asarray(rng.standard_normal((m, 64)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((3, 64, 32)) * 0.1).astype(
        np.float32))

    def loss(fn, x_, w_):
        out = fn(x_, w_)
        return (out * jnp.sin(jnp.arange(out.size).reshape(out.shape))).sum()

    kernel = lambda x_, w_: gmm_ops.grouped_matmul(
        x_, w_, jnp.asarray(sizes), force_pallas=True, interpret=True)
    oracle = lambda x_, w_: gmm_ref.grouped_matmul(x_, w_,
                                                   jnp.asarray(sizes))
    gk = jax.grad(lambda x_, w_: loss(kernel, x_, w_), argnums=(0, 1))(x, w)
    go = jax.grad(lambda x_, w_: loss(oracle, x_, w_), argnums=(0, 1))(x, w)
    for a, b in zip(gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-3)


def test_gmm_traced_sizes_fall_back_to_xla(rng):
    """Traced group_sizes can't drive host-side packing: the Pallas branch
    must fall back to the XLA path instead of dying on a tracer->numpy
    conversion."""
    sizes = np.array([12, 20], np.int32)
    x = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((2, 16, 8)) * 0.1).astype(
        np.float32))

    @jax.jit
    def f(x_, w_, sizes_):
        return gmm_ops.grouped_matmul(x_, w_, sizes_, force_pallas=True,
                                      interpret=True)

    got = f(x, w, jnp.asarray(sizes))  # sizes traced: jit argument
    want = gmm_ref.grouped_matmul(x, w, jnp.asarray(sizes))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


def test_raw_gmm_kernel_grad_raises_actionable(rng):
    sizes = np.array([128, 128], np.int32)
    x = jnp.asarray(rng.standard_normal((256, 128)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((2, 128, 128)) * 0.1).astype(
        np.float32))
    _, tile_group, _, _ = gmm_ops.pack_rows(x, sizes)
    with pytest.raises(NotImplementedError, match="REPRO_USE_PALLAS"):
        jax.grad(lambda x_: gmm_ops.grouped_matmul_pallas(
            x_, w, tile_group, interpret=True).sum())(x)


# ----------------------------------------------------------- fused attention
@pytest.mark.parametrize("shape", [(8, 3, 16, 2, 8), (16, 7, 50, 1, 128),
                                   (24, 5, 30, 4, 16)])
def test_gat_ell_kernel_sweep(rng, shape):
    """Fused flash-GAT kernel == panel oracle across (R, K, N, H, F)."""
    from repro.kernels.attention import ref as gat_ref
    from repro.kernels.attention.gat_attention import gat_ell_pallas
    rows, k, n, h, f = shape
    ell = rng.integers(-1, n, (rows, k)).astype(np.int32)
    ell[3] = -1  # an all-padding row must come out as a 0 row
    adst = jnp.asarray(rng.standard_normal((rows, h)).astype(np.float32))
    asrc = jnp.asarray(rng.standard_normal((n, h)).astype(np.float32))
    z = jnp.asarray(rng.standard_normal((n, h, f)).astype(np.float32))
    w = jnp.asarray(rng.random((rows, k)).astype(np.float32))
    for w_ in (None, w):
        a = gat_ref.gat_attend_panels(jnp.asarray(ell), adst, w_, asrc, z)
        b = gat_ell_pallas(jnp.asarray(ell), adst, w_, asrc,
                           z.reshape(n, h * f), interpret=True)
        np.testing.assert_allclose(np.asarray(a).reshape(rows, h * f),
                                   np.asarray(b), rtol=1e-5, atol=1e-5)
        assert np.abs(np.asarray(b)[3]).max() == 0.0


def test_gat_ell_grad_matches_oracle(rng):
    """The ops-level custom VJP: kernel-path gradients (alphas, weights AND
    features) == panel-oracle gradients."""
    from repro.kernels.attention import ops as attn_ops, ref as gat_ref
    rows, k, n, h, f = 16, 5, 23, 2, 16
    ell = jnp.asarray(rng.integers(-1, n, (rows, k)).astype(np.int32))
    adst = jnp.asarray(rng.standard_normal((rows, h)).astype(np.float32))
    asrc = jnp.asarray(rng.standard_normal((n, h)).astype(np.float32))
    z = jnp.asarray(rng.standard_normal((n, h, f)).astype(np.float32))
    w = jnp.asarray(rng.random((rows, k)).astype(np.float32))

    def loss(fn, adst_, w_, asrc_, z_):
        out = fn(adst_, w_, asrc_, z_)
        return (out * jnp.sin(jnp.arange(out.size).reshape(out.shape))).sum()

    kernel = lambda a_, w_, s_, z_: attn_ops._gat_ell_pallas_diff(
        0.2, True, ell, a_, w_, s_, z_)
    oracle = lambda a_, w_, s_, z_: gat_ref.gat_attend_panels(
        ell, a_, w_, s_, z_, negative_slope=0.2)
    gk = jax.grad(functools.partial(loss, kernel),
                  argnums=(0, 1, 2, 3))(adst, w, asrc, z)
    go = jax.grad(functools.partial(loss, oracle),
                  argnums=(0, 1, 2, 3))(adst, w, asrc, z)
    for a, b in zip(gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_gat_ell_grad_row_chunked(rng, monkeypatch):
    """The VJP covers the multi-launch (SMEM row-chunked) forward too."""
    from repro.kernels.attention import ops as attn_ops, ref as gat_ref
    monkeypatch.setattr(attn_ops, "MAX_PREFETCH_ELEMS", 64)
    rows, k, n, h, f = 40, 5, 23, 2, 16
    ell = jnp.asarray(rng.integers(-1, n, (rows, k)).astype(np.int32))
    adst = jnp.asarray(rng.standard_normal((rows, h)).astype(np.float32))
    asrc = jnp.asarray(rng.standard_normal((n, h)).astype(np.float32))
    z = jnp.asarray(rng.standard_normal((n, h, f)).astype(np.float32))
    gk = jax.grad(lambda z_: attn_ops._gat_ell_pallas_diff(
        0.2, True, ell, adst, None, asrc, z_).sum())(z)
    go = jax.grad(lambda z_: gat_ref.gat_attend_panels(
        ell, adst, None, asrc, z_).sum())(z)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(go), rtol=1e-4,
                               atol=1e-4)


def test_raw_gat_kernel_grad_raises_actionable(rng):
    from repro.kernels.attention.gat_attention import gat_ell_pallas
    ell = jnp.asarray(rng.integers(-1, 10, (8, 4)).astype(np.int32))
    adst = jnp.asarray(rng.standard_normal((8, 2)).astype(np.float32))
    asrc = jnp.asarray(rng.standard_normal((10, 2)).astype(np.float32))
    z2d = jnp.asarray(rng.standard_normal((10, 16)).astype(np.float32))
    with pytest.raises(NotImplementedError, match="REPRO_USE_PALLAS"):
        jax.grad(lambda z_: gat_ell_pallas(ell, adst, None, asrc, z_,
                                           interpret=True).sum())(z2d)


# ----------------------------------------------------------- segment softmax
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_segment_softmax_property(seed):
    """Each segment's outputs sum to 1 (where the segment is non-empty)."""
    r = np.random.default_rng(seed)
    rows, k = 16, int(r.integers(2, 20))
    vals = r.standard_normal((rows, k)).astype(np.float32)
    mask = r.random((rows, k)) > 0.4
    out = np.asarray(segment_softmax_pallas(
        jnp.asarray(vals), jnp.asarray(mask), interpret=True))
    ref = np.asarray(ss_ref.segment_softmax_ell(jnp.asarray(vals),
                                                jnp.asarray(mask)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    sums = (out * mask).sum(1)
    nonempty = mask.any(1)
    np.testing.assert_allclose(sums[nonempty], 1.0, rtol=1e-4)
    assert (np.abs(out[~mask]) < 1e-7).all()


def test_segment_softmax_pads_odd_panel_heights(rng):
    """Regression: R % block_rows != 0 used to hard-assert; now the panel
    is capacity-padded (masked) to the block multiple and sliced back."""
    vals = jnp.asarray(rng.standard_normal((10, 16)).astype(np.float32))
    mask = jnp.asarray(rng.random((10, 16)) < 0.7)
    out = segment_softmax_pallas(vals, mask, interpret=True)
    ref = ss_ref.segment_softmax_ell(vals, mask)
    assert out.shape == (10, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)


def test_segment_softmax_ell_ops_grad_matches_oracle(rng):
    """The ops-level padded-panel entry differentiates on the Pallas branch
    (custom VJP over the same panels) and matches the oracle gradient."""
    from repro.kernels.segment_softmax import ops as ss_ops
    vals = jnp.asarray(rng.standard_normal((12, 8)).astype(np.float32))
    mask = jnp.asarray(rng.random((12, 8)) < 0.7)
    gk = jax.grad(lambda v: (ss_ops.segment_softmax_ell(
        v, mask, force_pallas=True, interpret=True) ** 2).sum())(vals)
    go = jax.grad(lambda v: (ss_ref.segment_softmax_ell(
        v, mask) ** 2).sum())(vals)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(go), rtol=1e-4,
                               atol=1e-5)


def test_raw_segment_softmax_grad_raises_actionable(rng):
    vals = jnp.asarray(rng.standard_normal((8, 8)).astype(np.float32))
    mask = jnp.ones((8, 8), bool)
    with pytest.raises(NotImplementedError, match="REPRO_USE_PALLAS"):
        jax.grad(lambda v: segment_softmax_pallas(
            v, mask, interpret=True).sum())(vals)


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,s,h,d,causal", [
    (1, 128, 2, 64, True), (2, 256, 4, 64, True), (2, 128, 2, 128, False),
    (1, 384, 8, 32, True)])
def test_flash_attention_sweep(rng, b, s, h, d, causal):
    q = rng.standard_normal((b, s, h, d)).astype(np.float32)
    k = rng.standard_normal((b, s, h, d)).astype(np.float32)
    v = rng.standard_normal((b, s, h, d)).astype(np.float32)
    a = attn_ref.mha_reference(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), causal=causal)
    out = flash_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), causal=causal,
                                 block_q=128, block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(out), rtol=2e-4,
                               atol=2e-4)


def test_raw_flash_attention_grad_raises_actionable(rng):
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 16)).astype(np.float32))
    with pytest.raises(NotImplementedError, match="REPRO_USE_PALLAS"):
        jax.grad(lambda q_: flash_attention_pallas(
            q_, q, q, causal=True, interpret=True).sum())(q)


def test_flash_attention_bf16(rng):
    b, s, h, d = 1, 128, 2, 64
    mk = lambda: jnp.asarray(rng.standard_normal((b, s, h, d)),
                             jnp.bfloat16)
    q, k, v = mk(), mk(), mk()
    a = attn_ref.mha_reference(q, k, v, causal=True)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(out, np.float32), rtol=3e-2,
                               atol=3e-2)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_triangular_attention_property(seed):
    """Diagonal-banded causal schedule == reference, at ~half the FLOPs."""
    r = np.random.default_rng(seed)
    b, s = int(r.integers(1, 3)), int(r.integers(20, 400))
    hkv = int(r.choice([1, 2]))
    h = hkv * int(r.choice([1, 2]))
    d = int(r.choice([16, 32]))
    q = r.standard_normal((b, s, h, d)).astype(np.float32)
    k = r.standard_normal((b, s, hkv, d)).astype(np.float32)
    v = r.standard_normal((b, s, hkv, d)).astype(np.float32)
    a = attn_ref.mha_reference(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), causal=True)
    t = attn_ref.mha_chunked_causal(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(t), rtol=3e-4,
                               atol=3e-4)


def test_triangular_attention_halves_flops():
    from repro.launch import jaxpr_stats
    q = jax.ShapeDtypeStruct((1, 4096, 2, 64), jnp.float32)
    rect = jaxpr_stats.step_stats(
        lambda q, k, v: attn_ref.mha_chunked(q, k, v, causal=True,
                                             block_q=512, block_kv=512),
        q, q, q)["dot_flops"]
    tri = jaxpr_stats.step_stats(
        lambda q, k, v: attn_ref.mha_chunked_causal(q, k, v, block=512),
        q, q, q)["dot_flops"]
    n = 8
    assert abs(tri / rect - (n + 1) / (2 * n)) < 0.01


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_chunked_attention_property(seed):
    """Double-blocked chunked attention == reference for random GQA shapes."""
    r = np.random.default_rng(seed)
    b = int(r.integers(1, 3))
    s = int(r.integers(10, 300))
    hkv = int(r.choice([1, 2]))
    h = hkv * int(r.choice([1, 2, 4]))
    d = int(r.choice([16, 32]))
    q = r.standard_normal((b, s, h, d)).astype(np.float32)
    k = r.standard_normal((b, s, hkv, d)).astype(np.float32)
    v = r.standard_normal((b, s, hkv, d)).astype(np.float32)
    a = attn_ref.mha_reference(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), causal=True)
    c = attn_ref.mha_chunked(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             causal=True, block_q=64, block_kv=96)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=3e-4,
                               atol=3e-4)
