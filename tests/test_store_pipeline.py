"""PR 8: stage-pipelined producer, hot-row cache, mmap store, partitioner.

Covers the overlapped out-of-core loading layer:
  * pipelined loader == sequential loader, bit for bit, homo + hetero
  * on_batch_error policy / health-counter parity under deterministic
    faults, sequential vs pipelined, plus chaos-store invariants
  * consumer abandonment reaps every stage worker and the producer
  * HotRowCache / CachedFeatureStore semantics (seeded eviction, bounded
    capacity, correctness under thrash, stats, invalidation)
  * MmapFeatureStore budget gating + out-of-core streaming through a
    one-trace jit'd step
  * vectorized BFS partitioner: bit-parity vs the original deque
    formulation, determinism per seed
  * partition-aware seed ordering groups batches by home partition
"""

import threading
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.data import Data, HeteroData
from repro.data.feature_store import (CachedFeatureStore, HotRowCache,
                                      InMemoryFeatureStore,
                                      MemoryBudgetError, MmapFeatureStore,
                                      PartitionedFeatureStore)
from repro.data.graph_store import InMemoryGraphStore
from repro.data.hetero_sampler import HeteroNeighborLoader
from repro.data.loader import NeighborLoader
from repro.data.partition import build_partitioned_stores, partition_graph
from repro.data.resilience import (ChaosFeatureStore, FailureSchedule,
                                   TransientStoreError)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _homo_stores(rng, n=300, e=1800, feat=12):
    fs = InMemoryFeatureStore()
    fs.put_tensor(rng.standard_normal((n, feat)).astype(np.float32),
                  group="node", attr="x")
    fs.put_tensor(rng.integers(0, 4, n), group="node", attr="y")
    gs = InMemoryGraphStore()
    gs.put_edge_index(np.stack([rng.integers(0, n, e),
                                rng.integers(0, n, e)]), num_nodes=n)
    return fs, gs, n


def _assert_batches_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------- pipeline bit-parity
@pytest.mark.parametrize("prefetch", [0, 2])
def test_pipelined_batches_bit_identical_homo(rng, prefetch):
    fs, gs, n = _homo_stores(rng)

    def batches(**kw):
        return list(NeighborLoader(
            fs, gs, num_neighbors=[4, 3], batch_size=32, shuffle=True,
            seed=7, **kw))

    seq = batches(prefetch=0)
    pipe = batches(prefetch=prefetch, pipeline_depth=3)
    assert len(seq) == len(pipe) > 0
    for a, b in zip(seq, pipe):
        _assert_batches_equal(a, b)


def test_pipelined_batches_bit_identical_hetero(rng):
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((40, 8)).astype(np.float32))
    hd.add_nodes("item", rng.standard_normal((60, 8)).astype(np.float32))
    ub = np.stack([rng.integers(0, 40, 200), rng.integers(0, 60, 200)])
    et_ub, et_ru = ("user", "buys", "item"), ("item", "rev_buys", "user")
    hd.add_edges(et_ub, ub)
    hd.add_edges(et_ru, ub[::-1])
    fan = {et_ub: [3, 2], et_ru: [3, 2]}

    def batches(**kw):
        return list(HeteroNeighborLoader(
            hd, hd, num_neighbors=fan, input_type="item",
            input_nodes=np.arange(60), batch_size=16, shuffle=True, seed=3,
            **kw))

    seq = batches(prefetch=0)
    pipe = batches(prefetch=2, pipeline_depth=3)
    assert len(seq) == len(pipe) > 0
    for a, b in zip(seq, pipe):
        _assert_batches_equal(a, b)


class RowKeyedDegradingStore:
    """Degrades rows as a pure function of the requested row ids — the
    degraded mask is then invariant to gather interleaving, unlike a
    call-counter chaos schedule."""

    def __init__(self, inner):
        self.inner = inner

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def get_padded_resilient(self, index, **kw):
        x = np.array(self.inner.get_padded(index, **kw))
        idx = np.asarray(index)
        degraded = (idx >= 0) & (idx % 7 == 0)
        x[degraded] = 0.0
        return x, degraded


def test_pipelined_degraded_masks_identical(rng):
    """Degraded-row masks from a resilient-style store survive the pipeline
    unchanged (gather returns them; pack attaches them; health counts
    them the same as the sequential epoch)."""
    fs, gs, n = _homo_stores(rng)

    def run(**kw):
        ld = NeighborLoader(
            RowKeyedDegradingStore(fs), gs, num_neighbors=[3, 2],
            batch_size=30, shuffle=True, seed=5, **kw)
        return list(ld), dict(ld.health)

    seq, h_seq = run(prefetch=0)
    pipe, h_pipe = run(prefetch=2, pipeline_depth=2)
    assert len(seq) == len(pipe) > 0
    assert h_seq == h_pipe and h_seq["degraded_rows"] > 0
    for a, b in zip(seq, pipe):
        _assert_batches_equal(a, b)
        assert "degraded" in a.extras


def test_pipeline_depth_zero_and_one_are_sequential(rng):
    fs, gs, n = _homo_stores(rng)
    for depth in (0, 1):
        ld = NeighborLoader(fs, gs, num_neighbors=[3], batch_size=50,
                            pipeline_depth=depth, seed=0)
        assert len(list(ld)) == len(ld)
    with pytest.raises(ValueError, match="pipeline_depth"):
        NeighborLoader(fs, gs, num_neighbors=[3], batch_size=50,
                       pipeline_depth=-1, seed=0)


# ------------------------------------------- policy / health-counter parity
class SeedKeyedFlakyStore:
    """Store whose fetches fail deterministically per seed batch.

    Faults key on the batch's first seed row (seeds lead the sampled node
    list and are invariant under policy retries), so the fault pattern is
    identical however batches are pipelined, threaded, or re-attempted —
    unlike a call-counter chaos schedule, whose per-call streams see
    re-sampled node sets. ``fails_per_batch`` < policy attempts yields
    recoverable faults; larger values yield hard failures.
    """

    def __init__(self, inner, fail_every=3, fails_per_batch=1):
        self.inner = inner
        self.fail_every = fail_every
        self.fails_per_batch = fails_per_batch
        self.fails = {}
        self.lock = threading.Lock()

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def get_padded(self, index, **kw):
        idx = np.asarray(index)
        key = int(idx[idx >= 0][0])
        with self.lock:
            c = self.fails.get(key, 0)
            if c < self.fails_per_batch and key % self.fail_every == 0:
                self.fails[key] = c + 1
                raise TransientStoreError(f"flaky seed {key}")
        return self.inner.get_padded(index, **kw)


@pytest.mark.parametrize("policy", ["raise", "retry", "skip"])
@pytest.mark.parametrize("fails_per_batch", [1, 5])
def test_policy_health_parity_sequential_vs_pipelined(
        rng, policy, fails_per_batch):
    fs, gs, n = _homo_stores(rng)

    def run(depth):
        flaky = SeedKeyedFlakyStore(fs, fails_per_batch=fails_per_batch)
        ld = NeighborLoader(
            flaky, gs, num_neighbors=[3], batch_size=30, shuffle=True,
            labels_attr=None, on_batch_error=policy, batch_retries=2,
            pipeline_depth=depth, prefetch=2 if depth > 1 else 0, seed=5)
        try:
            produced = len(list(ld))
        except TransientStoreError:
            produced = "raised"
        return produced, dict(ld.health)

    assert run(1) == run(3)


def test_policy_health_counters_expected_values(rng):
    """Exact counter accounting on a known fault pattern (pipelined)."""
    fs, gs, n = _homo_stores(rng)
    flaky = SeedKeyedFlakyStore(fs, fail_every=1, fails_per_batch=5)
    ld = NeighborLoader(flaky, gs, num_neighbors=[3], batch_size=30,
                        shuffle=False, labels_attr=None,
                        on_batch_error="skip", batch_retries=2,
                        pipeline_depth=3, prefetch=2, seed=0)
    assert list(ld) == []
    nb = len(ld)
    # every batch: 1 failed attempt + 2 failed retries, then skipped
    assert ld.health == {"batches": 0, "batch_retries": 2 * nb,
                        "skipped_batches": nb, "degraded_rows": 0}


@pytest.mark.chaos
def test_pipelined_chaos_epoch_invariants(rng):
    """Against a genuinely racy chaos store the pipelined epoch still
    upholds the policy invariants: every seed batch accounted once,
    produced + skipped == total, counters self-consistent."""
    fs, gs, n = _homo_stores(rng)
    sched = FailureSchedule(seed=3, error_rate=0.4, sleep=lambda s: None)
    chaos = ChaosFeatureStore(fs, sched)
    ld = NeighborLoader(chaos, gs, num_neighbors=[3, 2], batch_size=30,
                        shuffle=True, labels_attr=None,
                        on_batch_error="skip", batch_retries=1,
                        pipeline_depth=4, prefetch=3, seed=9)
    produced = len(list(ld))
    h = ld.health
    assert h["batches"] == produced
    assert h["batches"] + h["skipped_batches"] == len(ld)
    assert h["batch_retries"] >= h["skipped_batches"]


# ----------------------------------------------------------- worker reaping
def _loading_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith(("loader-stage", "loader-producer"))]


def _assert_reaped(deadline_s=5.0):
    deadline = time.monotonic() + deadline_s
    while _loading_threads() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not _loading_threads()


@pytest.mark.parametrize("kw", [
    {"prefetch": 3, "pipeline_depth": 4},
    {"prefetch": 0, "pipeline_depth": 4},
    {"prefetch": 3, "pipeline_depth": 1},
])
def test_abandoned_consumer_reaps_all_workers(rng, kw):
    fs, gs, n = _homo_stores(rng, n=500, e=2500)
    ld = NeighborLoader(fs, gs, num_neighbors=[4], batch_size=25,
                        labels_attr=None, seed=0, **kw)
    it = iter(ld)
    next(it)
    next(it)
    it.close()  # consumer walks away mid-epoch
    _assert_reaped()


def test_exhausted_epoch_leaves_no_workers(rng):
    fs, gs, n = _homo_stores(rng)
    ld = NeighborLoader(fs, gs, num_neighbors=[3], batch_size=50,
                        prefetch=2, pipeline_depth=3, seed=0)
    assert len(list(ld)) == len(ld)
    _assert_reaped()


def test_slow_consumer_abandonment_with_blocked_producer(rng):
    """Abandoning while the producer is blocked on a full prefetch queue
    must still unblock and join everything."""
    fs, gs, n = _homo_stores(rng, n=600, e=3000)
    ld = NeighborLoader(fs, gs, num_neighbors=[4, 2], batch_size=20,
                        prefetch=1, pipeline_depth=3, seed=0)
    it = iter(ld)
    next(it)
    time.sleep(0.1)  # let the producer fill the queue and block on put
    it.close()
    _assert_reaped()


# ------------------------------------------------------------- hot-row cache
def test_hot_row_cache_roundtrip_and_hits(rng):
    cache = HotRowCache(num_rows=100, capacity=8, seed=0)
    vals = rng.standard_normal((3, 4)).astype(np.float32)
    rows = np.array([5, 17, 40])
    out, have = cache.lookup(rows)
    assert not have.any()
    cache.insert(rows, vals)
    out, have = cache.lookup(rows)
    assert have.all()
    np.testing.assert_array_equal(out, vals)


def test_hot_row_cache_capacity_bound_and_eviction_determinism(rng):
    # batches small vs capacity so the sampled-LFU candidate window is a
    # strict (seeded) subset of the occupied slots
    def fill(seed):
        cache = HotRowCache(num_rows=1000, capacity=64, seed=seed)
        for lo in range(0, 400, 8):
            rows = np.arange(lo, lo + 8)
            cache.insert(rows, np.full((8, 2), lo, np.float32))
        return cache

    a, b = fill(3), fill(3)
    assert (a.owner >= 0).sum() <= 64
    np.testing.assert_array_equal(a.owner, b.owner)  # seeded eviction
    c = fill(4)
    assert not np.array_equal(a.owner, c.owner)  # seed actually matters


def test_hot_row_cache_correct_under_eviction_pressure(rng):
    n, feat = 400, 6
    ref = rng.standard_normal((n, feat)).astype(np.float32)
    cache = HotRowCache(num_rows=n, capacity=32, seed=1)
    for _ in range(50):
        rows = rng.integers(0, n, 20)
        out, have = cache.lookup(rows)
        if have.any():  # lookup returns values for the cached subset only
            np.testing.assert_array_equal(out, ref[rows[have]])
        cache.insert(rows[~have], ref[rows[~have]])


def test_cached_store_matches_inner_and_counts(rng):
    n, feat = 200, 8
    inner = InMemoryFeatureStore()
    x = rng.standard_normal((n, feat)).astype(np.float32)
    inner.put_tensor(x, group="node", attr="x")
    cached = CachedFeatureStore(inner, capacity=64, seed=0)
    for _ in range(30):
        idx = rng.integers(-1, n, 25)  # includes pad rows
        got = cached.get_padded(idx, group="node", attr="x")
        want = inner.get_padded(idx, group="node", attr="x")
        np.testing.assert_array_equal(got, want)
    s = cached.stats
    assert s["requests"] == 30
    assert s["hits"] + s["misses"] > 0
    assert 0.0 < cached.hit_rate() < 1.0


def test_cached_store_put_invalidates(rng):
    inner = InMemoryFeatureStore()
    inner.put_tensor(np.zeros((10, 2), np.float32), group="node", attr="x")
    cached = CachedFeatureStore(inner, capacity=8, seed=0)
    idx = np.arange(4)
    cached.get_padded(idx, group="node", attr="x")  # warm the cache
    cached.put_tensor(np.ones((10, 2), np.float32), group="node", attr="x")
    np.testing.assert_array_equal(
        cached.get_padded(idx, group="node", attr="x"),
        np.ones((4, 2), np.float32))


def test_reset_stats_walks_wrapper_chain(rng):
    inner = PartitionedFeatureStore(2)
    inner.put_tensor(rng.standard_normal((20, 4)).astype(np.float32),
                     group="node", attr="x")
    cached = CachedFeatureStore(inner, capacity=8, seed=0)
    cached.get_padded(np.arange(6), group="node", attr="x")
    assert cached.stats["requests"] > 0 and inner.stats["requests"] > 0
    assert cached.reset_stats() is cached
    assert all(v == 0 for v in cached.stats.values())
    assert all(v == 0 for v in inner.stats.values())


def test_cached_partitioned_loader_end_to_end(rng):
    """Cache composes under the loader over a partitioned store and batches
    stay bit-identical to the uncached path."""
    ei, x = (np.stack([rng.integers(0, 150, 900),
                       rng.integers(0, 150, 900)]),
             rng.standard_normal((150, 8)).astype(np.float32))
    fs, gs, part = build_partitioned_stores(x, ei, 3)

    def batches(store):
        return list(NeighborLoader(
            store, gs, num_neighbors=[4, 2], batch_size=25, shuffle=True,
            labels_attr=None, pipeline_depth=2, prefetch=2, seed=2))

    plain = batches(fs)
    cached_store = CachedFeatureStore(fs, capacity=64, seed=0)
    cached = batches(cached_store)
    for a, b in zip(plain, cached):
        _assert_batches_equal(a, b)
    assert cached_store.stats["hits"] > 0


# ------------------------------------------------------------ mmap features
def test_mmap_store_budget_gates_full_reads(rng, tmp_path):
    n, feat = 64, 16
    mfs = MmapFeatureStore(str(tmp_path),
                           memory_budget_bytes=n * feat * 4 // 2)
    mfs.put_tensor(rng.standard_normal((n, feat)).astype(np.float32),
                   group="node", attr="x")
    with pytest.raises(MemoryBudgetError):
        mfs.get_tensor(group="node", attr="x")
    small = mfs.get_tensor(group="node", attr="x", index=np.arange(8))
    assert small.shape == (8, feat)
    big = np.arange(n)
    with pytest.raises(MemoryBudgetError):
        mfs.get_tensor(group="node", attr="x", index=np.repeat(big, 2))


def test_mmap_store_reattach_existing_root(rng, tmp_path):
    n, feat = 32, 4
    x = rng.standard_normal((n, feat)).astype(np.float32)
    first = MmapFeatureStore(str(tmp_path), memory_budget_bytes=1 << 20)
    first.put_tensor(x, group="node", attr="x")
    again = MmapFeatureStore(str(tmp_path), memory_budget_bytes=1 << 20)
    np.testing.assert_array_equal(
        again.get_tensor(group="node", attr="x", index=np.arange(5)),
        x[:5])
    with pytest.raises(KeyError):
        again.get_tensor(group="node", attr="missing")


def test_mmap_out_of_core_epoch_single_trace(rng, tmp_path):
    """Features 4x over budget stream through a jit'd step, one trace."""
    from repro.analysis.retrace import RetraceSentinel

    n, feat = 600, 32
    # whole matrix 3x over budget, but one batch's gather fits under it
    budget = n * feat * 4 // 3
    mfs = MmapFeatureStore(str(tmp_path), memory_budget_bytes=budget)
    mm = mfs.create_tensor((n, feat), np.float32, group="node", attr="x")
    for lo in range(0, n, 128):  # chunked fill, never whole-matrix
        hi = min(lo + 128, n)
        mm[lo:hi] = rng.standard_normal((hi - lo, feat)).astype(np.float32)
    mm.flush()
    mfs.put_tensor(rng.integers(0, 4, n), group="node", attr="y")
    gs = InMemoryGraphStore()
    gs.put_edge_index(np.stack([rng.integers(0, n, 3600),
                                rng.integers(0, n, 3600)]), num_nodes=n)
    loader = NeighborLoader(mfs, gs, num_neighbors=[3, 2], batch_size=16,
                            shuffle=True, pipeline_depth=3, prefetch=2,
                            seed=0)
    params = {"w": jnp.zeros((feat, 4))}
    sentinel = RetraceSentinel(budget=1)

    @jax.jit
    def step(p, batch):
        out = batch.edge_index.matmul(batch.x @ p["w"], force_pallas=False)
        return (out[batch.seed_slots] ** 2).mean()

    step = sentinel.wrap(step, name="ooc_step")
    nb = 0
    for batch in loader:
        step(params, batch).block_until_ready()
        nb += 1
    assert nb == len(loader) > 0
    assert sentinel.count("ooc_step") == 1
    assert mfs.stats["rows_read"] > 0


# -------------------------------------------------- vectorized partitioner
def _partition_graph_reference(num_nodes, edge_index, num_parts, seed=0):
    """The original deque/FIFO formulation (pre-vectorization), verbatim —
    the parity oracle for the numpy frontier version."""
    rng = np.random.default_rng(seed)
    src, dst = np.asarray(edge_index[0]), np.asarray(edge_index[1])
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    order = np.argsort(s2, kind="stable")
    src_s, dst_s = s2[order], d2[order]
    indptr = np.searchsorted(src_s, np.arange(num_nodes + 1))
    part = np.full(num_nodes, -1, np.int64)
    target = -(-num_nodes // num_parts)
    perm = rng.permutation(num_nodes)
    root_iter = iter(perm)
    for p in range(num_parts):
        count = 0
        queue = deque()
        while count < target:
            if not queue:
                root = next((r for r in root_iter if part[r] < 0), None)
                if root is None:
                    break
                queue.append(int(root))
            v = queue.popleft()
            if part[v] >= 0:
                continue
            part[v] = p
            count += 1
            for u in dst_s[indptr[v]:indptr[v + 1]]:
                if part[u] < 0:
                    queue.append(int(u))
    part[part < 0] = num_parts - 1
    return part


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bfs_partitioner_parity_with_reference(seed):
    rng = np.random.default_rng(100 + seed)
    for _ in range(8):
        n = int(rng.integers(20, 250))
        e = int(rng.integers(0, 4 * n))
        ei = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)])
        parts = int(rng.integers(2, 6))
        got = partition_graph(n, ei, parts, method="bfs", seed=seed)
        want = _partition_graph_reference(n, ei, parts, seed=seed)
        np.testing.assert_array_equal(got, want)


def test_bfs_partitioner_deterministic_and_covering(rng):
    n = 500
    ei = np.stack([rng.integers(0, n, 2000), rng.integers(0, n, 2000)])
    a = partition_graph(n, ei, 4, method="bfs", seed=7)
    b = partition_graph(n, ei, 4, method="bfs", seed=7)
    np.testing.assert_array_equal(a, b)
    assert set(np.unique(a)) <= set(range(4))
    assert (a >= 0).all()
    # balanced up to the ceil target
    assert np.bincount(a, minlength=4).max() <= -(-n // 4)
    with pytest.raises(ValueError, match="unknown partition method"):
        partition_graph(n, ei, 4, method="metis")


def test_bfs_partitioner_isolated_nodes_and_empty_graph():
    ei = np.zeros((2, 0), np.int64)
    part = partition_graph(10, ei, 3, method="bfs", seed=0)
    assert part.shape == (10,) and (part >= 0).all()
    ref = _partition_graph_reference(10, ei, 3, seed=0)
    np.testing.assert_array_equal(part, ref)


# ------------------------------------------------ partition-aware ordering
def test_partition_order_groups_seed_batches(rng):
    ei = np.stack([rng.integers(0, 400, 2400), rng.integers(0, 400, 2400)])
    x = rng.standard_normal((400, 8)).astype(np.float32)
    fs, gs, part = build_partitioned_stores(x, ei, 4, method="bfs")

    def seed_parts(po):
        ld = NeighborLoader(fs, gs, num_neighbors=[3], batch_size=50,
                            shuffle=True, partition_order=po,
                            labels_attr=None, seed=0)
        out = []
        for b in ld:
            ids = np.asarray(b.n_id)[np.asarray(b.seed_slots)]
            out.append(np.unique(part[ids[ids >= 0]]))
        return out

    grouped = seed_parts(True)
    scattered = seed_parts(False)
    assert sum(len(u) for u in grouped) < sum(len(u) for u in scattered)
    # full batches touch exactly one home partition when sizes allow
    assert all(len(u) == 1 for u in grouped[:-1])


def test_partition_order_noop_without_routing_store(rng):
    """Against a non-routing store the flag degrades to plain shuffle."""
    fs, gs, n = _homo_stores(rng)

    def batches(po):
        return list(NeighborLoader(fs, gs, num_neighbors=[3], batch_size=50,
                                   shuffle=True, partition_order=po,
                                   seed=4))

    for a, b in zip(batches(False), batches(True)):
        _assert_batches_equal(a, b)


def test_partition_order_pipelined_parity(rng):
    """partition_order composes with the pipeline: same batches as the
    sequential partition-ordered epoch."""
    ei = np.stack([rng.integers(0, 300, 1500), rng.integers(0, 300, 1500)])
    x = rng.standard_normal((300, 8)).astype(np.float32)
    fs, gs, part = build_partitioned_stores(x, ei, 3, method="bfs")

    def batches(**kw):
        return list(NeighborLoader(fs, gs, num_neighbors=[4, 2],
                                   batch_size=30, shuffle=True,
                                   partition_order=True, labels_attr=None,
                                   seed=6, **kw))

    for a, b in zip(batches(prefetch=0),
                    batches(prefetch=2, pipeline_depth=3)):
        _assert_batches_equal(a, b)
