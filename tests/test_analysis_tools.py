"""jaxpr FLOP counter + HLO collective parser (roofline instrumentation)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis, jaxpr_stats


def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    stats = jaxpr_stats.step_stats(f, a, b)
    assert stats["dot_flops"] == 2 * 32 * 64 * 128


def test_scan_multiplies_trip_count():
    def f(x):
        def body(c, _):
            return c @ c, None

        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    stats = jaxpr_stats.step_stats(f, x)
    assert stats["dot_flops"] == 7 * 2 * 16 * 16 * 16


def test_nested_scan_and_remat():
    def inner(x):
        def body(c, _):
            return c @ c, None

        return jax.lax.scan(body, x, None, length=3)[0]

    def f(x):
        def body(c, _):
            return jax.checkpoint(inner)(c), None

        return jax.lax.scan(body, x, None, length=5)[0]

    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    stats = jaxpr_stats.step_stats(f, x)
    assert stats["dot_flops"] == 5 * 3 * 2 * 8 * 8 * 8


def test_grad_counts_fwd_and_bwd():
    def f(w, x):
        return ((x @ w) ** 2).sum()

    w = jax.ShapeDtypeStruct((16, 24), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    fwd = jaxpr_stats.step_stats(f, w, x)["dot_flops"]
    both = jaxpr_stats.step_stats(jax.grad(f, argnums=(0, 1)), w, x)[
        "dot_flops"]
    assert both >= 2.9 * fwd  # fwd + dW + dX matmuls


def test_batched_dot_general():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    stats = jaxpr_stats.step_stats(f, a, b)
    assert stats["dot_flops"] == 4 * 2 * 8 * 16 * 32


def test_pallas_spmm_cost_exact():
    """pallas_call eqns are costed by the per-kernel analytic model (the
    kernel body is opaque to the generic eqn walk)."""
    from repro.kernels.spmm import ops as spmm_ops

    rng = np.random.default_rng(3)
    n, f = 64, 32
    indptr = np.arange(n + 1) * 4
    indices = rng.integers(0, n, 4 * n).astype(np.int32)
    ell_idx, _ = spmm_ops.csr_to_ell(indptr, indices)

    def fwd(x):
        return spmm_ops.spmm_ell(jnp.asarray(ell_idx), None, x,
                                 force_pallas=True, interpret=True)

    x = jax.ShapeDtypeStruct((n, f), jnp.float32)
    stats = jaxpr_stats.step_stats(fwd, x)
    r, k = ell_idx.shape
    assert stats["pallas_flops"] == 2 * r * k * f
    assert stats["pallas_flops"] <= stats["total_flops"]  # + glue eltwise
    assert stats["major_bytes"] >= r * k * 4  # at least the prefetch table


def test_pallas_cost_generic_fallback():
    """An unknown kernel name still contributes (out-elems, io-bytes)."""
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2

    def fwd(x):
        return pl.pallas_call(
            kernel, out_shape=jax.ShapeDtypeStruct((8, 16), jnp.float32),
            interpret=True)(x)

    stats = jaxpr_stats.step_stats(
        fwd, jax.ShapeDtypeStruct((8, 16), jnp.float32))
    assert stats["pallas_flops"] == 8 * 16
    assert stats["major_bytes"] == 2 * 8 * 16 * 4


SAMPLE_HLO = """
HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(f32[8]{0} %x), replica_groups={}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[128,64]) -> f32[128,64] {
  %a = f32[128,64] parameter(0)
  %ag = f32[128,64]{1,0} all-gather(f32[128,16]{1,0} %a), dimensions={1}
  %init = (s32[], f32[8]) tuple(s32[] constant(0), f32[8] constant(0))
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[128,64] copy(%ag)
}
"""


def test_hlo_collectives_with_trip_counts():
    stats = hlo_analysis.collective_stats(SAMPLE_HLO)
    # all-gather in entry: once, operand f32[128,16] = 8192 B
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 128 * 16 * 4
    # all-reduce inside the while body: 12 executions x 32 B
    assert stats["all-reduce"]["count"] == 12
    assert stats["all-reduce"]["bytes"] == 12 * 8 * 4


def test_sharding_specs_divisible_for_all_archs():
    """Every param spec must divide evenly on the production meshes."""
    from jax.sharding import AbstractMesh
    from repro.configs import ARCH_IDS, get_config
    from repro.distributed import sharding as shard_lib
    from repro.launch import specs as specs_lib

    for mesh_shape, axes in (((16, 16), ("data", "model")),
                             ((2, 16, 16), ("pod", "data", "model"))):
        # jax 0.4.37 AbstractMesh signature: a ((name, size), ...) tuple
        # (newer jax takes (shape, axis_names) — pass the portable form).
        mesh = AbstractMesh(tuple(zip(axes, mesh_shape)))
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            params = specs_lib.abstract_params(cfg)
            for profile in ("2d", "fsdp"):
                flat = jax.tree_util.tree_flatten_with_path(params)[0]
                for path, leaf in flat:
                    spec = shard_lib.param_spec(path, leaf, mesh, profile)
                    for dim, ax in enumerate(spec):
                        if ax is None:
                            continue
                        n = shard_lib._axis_size(mesh, ax)
                        assert leaf.shape[dim] % n == 0, (
                            arch, profile, path, leaf.shape, spec)
