"""Feature/graph stores (paper C6/C10): interfaces, partitioning, routing."""

import numpy as np
import pytest

from repro.data.data import Data, HeteroData
from repro.data.feature_store import (InMemoryFeatureStore,
                                      PartitionedFeatureStore)
from repro.data.loader import NeighborLoader
from repro.data.partition import build_partitioned_stores, partition_graph


def test_in_memory_store_roundtrip(rng):
    fs = InMemoryFeatureStore()
    x = rng.standard_normal((10, 4)).astype(np.float32)
    fs.put_tensor(x, group="node", attr="x")
    np.testing.assert_array_equal(fs.get_tensor(group="node", attr="x"), x)
    np.testing.assert_array_equal(
        fs.get_tensor(group="node", attr="x", index=np.array([3, 1])),
        x[[3, 1]])
    assert fs.get_tensor_size(group="node", attr="x") == (10, 4)


def test_get_padded_zero_rows(rng):
    fs = InMemoryFeatureStore()
    x = rng.standard_normal((5, 3)).astype(np.float32)
    fs.put_tensor(x)
    out = fs.get_padded(np.array([2, -1, 4]))
    np.testing.assert_array_equal(out[0], x[2])
    assert (out[1] == 0).all()
    np.testing.assert_array_equal(out[2], x[4])


def test_partitioned_store_matches_plain(rng):
    x = rng.standard_normal((40, 6)).astype(np.float32)
    fs = PartitionedFeatureStore(num_parts=4)
    fs.put_tensor(x)
    idx = rng.integers(0, 40, 25)
    np.testing.assert_array_equal(fs.get_tensor(index=idx), x[idx])
    assert fs.stats["remote_rows"] > 0  # block-cyclic -> mostly remote


def test_partition_methods_cover_all_nodes(rng):
    ei, n = np.stack([rng.integers(0, 100, 500),
                      rng.integers(0, 100, 500)]), 100
    for method in ("hash", "bfs"):
        part = partition_graph(n, ei, 4, method=method)
        assert part.min() >= 0 and part.max() < 4
        counts = np.bincount(part, minlength=4)
        assert counts.max() - counts.min() <= n // 4 + 1


def test_loader_oblivious_to_partitioning(rng):
    """Swapping InMemory -> Partitioned must not change loader output
    structure (the paper's plug-and-play claim)."""
    n = 80
    ei = np.stack([rng.integers(0, n, 400), rng.integers(0, n, 400)])
    x = rng.standard_normal((n, 8)).astype(np.float32)
    data = Data(x=x, edge_index=ei, y=rng.integers(0, 3, n))
    fs, gs, part = build_partitioned_stores(
        x, ei, 4, y=np.asarray(data.y))
    la = NeighborLoader(data, data, num_neighbors=[3], batch_size=8, seed=5)
    lb = NeighborLoader(fs, gs, num_neighbors=[3], batch_size=8, seed=5)
    a, b = next(iter(la)), next(iter(lb))
    np.testing.assert_array_equal(np.asarray(a.n_id), np.asarray(b.n_id))
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x))


def test_get_padded_single_fetch_no_double_count(rng):
    """The dtype probe must not issue a second backend fetch: one
    get_padded == one request, and valid rows counted exactly once."""
    x = rng.standard_normal((20, 4)).astype(np.float32)
    fs = PartitionedFeatureStore(num_parts=4)
    fs.put_tensor(x)
    fs.stats.update(local_rows=0, remote_rows=0, requests=0)
    out = fs.get_padded(np.array([3, -1, 7, 11, -1]))
    assert fs.stats["requests"] == 1
    assert fs.stats["local_rows"] + fs.stats["remote_rows"] == 3
    np.testing.assert_array_equal(out[[0, 2, 3]], x[[3, 7, 11]])
    assert (out[[1, 4]] == 0).all()


def test_get_padded_all_pads_on_empty_store(rng):
    """All-invalid index: an empty fetch derives dtype/shape without
    touching row 0 (which doesn't exist on an empty store)."""
    fs = InMemoryFeatureStore()
    fs.put_tensor(np.zeros((0, 5), np.float32))
    out = fs.get_padded(np.array([-1, -1, -1]))
    assert out.shape == (3, 5) and (out == 0).all()


def test_put_edge_index_explicit_zero_num_nodes():
    """num_nodes=0 is a real value (empty graph), not 'not given' — must
    not fall through to src.max() on empty arrays."""
    from repro.data.graph_store import InMemoryGraphStore

    gs = InMemoryGraphStore()
    gs.put_edge_index(np.zeros((2, 0), np.int64), num_nodes=0)
    csr = gs.get_csr()
    assert csr.num_rows == 0 and csr.num_edges == 0


def test_partitioned_store_empty_partition_zero(rng):
    """num_parts > num_rows leaves partition 0 potentially empty (and a
    skewed custom route certainly does): dtype/feature-dim must come from
    any non-empty partition."""
    x = rng.standard_normal((3, 6)).astype(np.float32)
    fs = PartitionedFeatureStore(num_parts=5)
    # custom route that leaves partition 0 (and 4) empty
    fs.put_partitioned(("node", "x"), x, np.array([1, 2, 3]))
    assert fs.get_tensor_size(group="node", attr="x") == (3, 6)
    np.testing.assert_array_equal(
        fs.get_tensor(index=np.array([2, 0])), x[[2, 0]])


def test_partitioned_stats_thread_safe_under_concurrent_get(rng):
    """The resilient fan-out issues concurrent per-partition gets; the
    stats counters must not lose updates (seeded, no sleeps)."""
    import threading

    x = rng.standard_normal((100, 4)).astype(np.float32)
    fs = PartitionedFeatureStore(num_parts=4)
    fs.put_tensor(x)
    fs.stats.update(local_rows=0, remote_rows=0, requests=0)
    n_threads, n_calls, n_rows = 8, 25, 40
    idx = [rng.integers(0, 100, n_rows) for _ in range(n_threads)]

    def worker(i):
        for _ in range(n_calls):
            np.testing.assert_allclose(fs.get_tensor(index=idx[i]),
                                       x[idx[i]])

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert fs.stats["requests"] == n_threads * n_calls
    assert (fs.stats["local_rows"] + fs.stats["remote_rows"]
            == n_threads * n_calls * n_rows)


def test_hetero_data_interfaces(rng):
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((10, 4)).astype(np.float32))
    hd.add_nodes("item", rng.standard_normal((20, 4)).astype(np.float32))
    hd.add_edges(("user", "buys", "item"),
                 np.stack([rng.integers(0, 10, 30),
                           rng.integers(0, 20, 30)]))
    assert hd.node_types() == ["user", "item"]
    assert ("user", "buys", "item") in hd.edge_types()
    csr = hd.get_csr(("user", "buys", "item"))
    assert csr.num_edges == 30
    # rev CSR cache is independent
    rev = hd.get_rev_csr(("user", "buys", "item"))
    assert rev.num_edges == 30
