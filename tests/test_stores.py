"""Feature/graph stores (paper C6/C10): interfaces, partitioning, routing."""

import numpy as np
import pytest

from repro.data.data import Data, HeteroData
from repro.data.feature_store import (InMemoryFeatureStore,
                                      PartitionedFeatureStore)
from repro.data.loader import NeighborLoader
from repro.data.partition import build_partitioned_stores, partition_graph


def test_in_memory_store_roundtrip(rng):
    fs = InMemoryFeatureStore()
    x = rng.standard_normal((10, 4)).astype(np.float32)
    fs.put_tensor(x, group="node", attr="x")
    np.testing.assert_array_equal(fs.get_tensor(group="node", attr="x"), x)
    np.testing.assert_array_equal(
        fs.get_tensor(group="node", attr="x", index=np.array([3, 1])),
        x[[3, 1]])
    assert fs.get_tensor_size(group="node", attr="x") == (10, 4)


def test_get_padded_zero_rows(rng):
    fs = InMemoryFeatureStore()
    x = rng.standard_normal((5, 3)).astype(np.float32)
    fs.put_tensor(x)
    out = fs.get_padded(np.array([2, -1, 4]))
    np.testing.assert_array_equal(out[0], x[2])
    assert (out[1] == 0).all()
    np.testing.assert_array_equal(out[2], x[4])


def test_partitioned_store_matches_plain(rng):
    x = rng.standard_normal((40, 6)).astype(np.float32)
    fs = PartitionedFeatureStore(num_parts=4)
    fs.put_tensor(x)
    idx = rng.integers(0, 40, 25)
    np.testing.assert_array_equal(fs.get_tensor(index=idx), x[idx])
    assert fs.stats["remote_rows"] > 0  # block-cyclic -> mostly remote


def test_partition_methods_cover_all_nodes(rng):
    ei, n = np.stack([rng.integers(0, 100, 500),
                      rng.integers(0, 100, 500)]), 100
    for method in ("hash", "bfs"):
        part = partition_graph(n, ei, 4, method=method)
        assert part.min() >= 0 and part.max() < 4
        counts = np.bincount(part, minlength=4)
        assert counts.max() - counts.min() <= n // 4 + 1


def test_loader_oblivious_to_partitioning(rng):
    """Swapping InMemory -> Partitioned must not change loader output
    structure (the paper's plug-and-play claim)."""
    n = 80
    ei = np.stack([rng.integers(0, n, 400), rng.integers(0, n, 400)])
    x = rng.standard_normal((n, 8)).astype(np.float32)
    data = Data(x=x, edge_index=ei, y=rng.integers(0, 3, n))
    fs, gs, part = build_partitioned_stores(
        x, ei, 4, y=np.asarray(data.y))
    la = NeighborLoader(data, data, num_neighbors=[3], batch_size=8, seed=5)
    lb = NeighborLoader(fs, gs, num_neighbors=[3], batch_size=8, seed=5)
    a, b = next(iter(la)), next(iter(lb))
    np.testing.assert_array_equal(np.asarray(a.n_id), np.asarray(b.n_id))
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x))


def test_hetero_data_interfaces(rng):
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((10, 4)).astype(np.float32))
    hd.add_nodes("item", rng.standard_normal((20, 4)).astype(np.float32))
    hd.add_edges(("user", "buys", "item"),
                 np.stack([rng.integers(0, 10, 30),
                           rng.integers(0, 20, 30)]))
    assert hd.node_types() == ["user", "item"]
    assert ("user", "buys", "item") in hd.edge_types()
    csr = hd.get_csr(("user", "buys", "item"))
    assert csr.num_edges == 30
    # rev CSR cache is independent
    rev = hd.get_rev_csr(("user", "buys", "item"))
    assert rev.num_edges == 30
