"""Fault tolerance + elastic + compression + optimizer (deliverables: FT).

Checkpoint/restart is exercised exactly the way production uses it:
train N steps with a checkpoint cadence, kill the loop mid-run (simulated
failure), restart from disk, and assert the resumed run matches an
uninterrupted one bit-for-bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import checkpoint as ckpt
from repro.distributed import compression as comp
from repro.distributed.elastic import StragglerMonitor
from repro.nn.lm import model as M
from repro.train import data_pipeline, optimizer as opt_lib, steps
from repro.train.loop import SimulatedFailure, train_loop


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_4b", smoke=True)
    ocfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    state = opt_lib.init_state(params, ocfg)
    step = jax.jit(steps.make_train_step(cfg, ocfg))
    return cfg, ocfg, state, step


def _batches(cfg, seed=0):
    return data_pipeline.synthetic_batches(cfg, 2, 16, seed=seed,
                                           prefetch=0)


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, ocfg, state, step = setup
    ckpt.save_checkpoint(tmp_path, 7, state)
    assert ckpt.latest_step(tmp_path) == 7
    restored = ckpt.restore_checkpoint(tmp_path, 7, state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_keeps_last_k(tmp_path, setup):
    cfg, ocfg, state, step = setup
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(tmp_path, s, state, keep=2)
    assert ckpt.all_steps(tmp_path) == [4, 5]


def test_failure_resume_bit_exact(tmp_path, setup):
    """Uninterrupted vs killed-and-resumed runs must converge identically."""
    cfg, ocfg, state, step = setup
    # uninterrupted 12 steps (fresh deterministic batches)
    out_a = train_loop(state, step, _batches(cfg), num_steps=12,
                       log_fn=lambda *a: None)
    # interrupted at step 8 with checkpoints every 4
    with pytest.raises(SimulatedFailure):
        train_loop(state, step, _batches(cfg), num_steps=12,
                   ckpt_dir=tmp_path / "ft", ckpt_every=4, fail_at=8,
                   log_fn=lambda *a: None)
    assert ckpt.latest_step(tmp_path / "ft") == 8
    # resume: the loop must restart from step 8 and replay 9..12.
    # deterministic pipeline: skip the first 8 batches on restart
    it = _batches(cfg)
    for _ in range(8):
        next(it)
    out_b = train_loop(state, step, it, num_steps=12,
                       ckpt_dir=tmp_path / "ft", ckpt_every=4,
                       log_fn=lambda *a: None)
    np.testing.assert_allclose(out_a["history"][-1][1],
                               out_b["history"][-1][1], rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(out_a["state"].params),
                    jax.tree_util.tree_leaves(out_b["state"].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(tmp_path, setup):
    cfg, ocfg, state, step = setup
    th = ckpt.save_checkpoint(tmp_path / "async", 3, state,
                              async_write=True)
    th.join()
    assert ckpt.latest_step(tmp_path / "async") == 3


def test_elastic_reshard_different_mesh(tmp_path, setup):
    """Save, then restore with a (different) mesh's shardings — the elastic
    restart path. On 1 device the mesh is trivial but the device_put +
    NamedSharding machinery is fully exercised."""
    from repro.distributed import sharding as shard_lib
    from repro.launch.mesh import make_local_mesh
    cfg, ocfg, state, step = setup
    ckpt.save_checkpoint(tmp_path / "el", 5, state)
    mesh = make_local_mesh(1, 1)
    shardings = shard_lib.state_shardings(mesh, state)
    restored = ckpt.restore_checkpoint(tmp_path / "el", 5, state,
                                       mesh=mesh, shardings=shardings)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_detects_slow_host():
    mon = StragglerMonitor(num_hosts=4, min_steps=3)
    for _ in range(5):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 2.1)
    m = mon.check()
    assert m.kind == "rebalance" and m.host == 2
    shares = mon.rebalanced_shares()
    assert shares[2] < shares[0]
    # evict threshold (needs >2 hosts for a meaningful median)
    mon2 = StragglerMonitor(num_hosts=4, min_steps=1)
    for _ in range(3):
        for h in range(3):
            mon2.record(h, 1.0)
        mon2.record(3, 10.0)
    assert mon2.check().kind == "evict"


def test_int8_compression_roundtrip(rng):
    tree = {"a": jnp.asarray(rng.standard_normal((32, 16)).astype(
        np.float32)), "b": jnp.asarray(rng.standard_normal(7).astype(
            np.float32))}
    res = comp.init_residual(tree)
    payload, new_res = comp.compress_grads(tree, res)
    deq = comp.decompress_grads(payload, tree)
    for k in tree:
        err = np.abs(np.asarray(deq[k]) - np.asarray(tree[k])).max()
        scale = float(np.abs(np.asarray(tree[k])).max()) / 127
        assert err <= scale * 0.5001 + 1e-7
        # residual carries exactly the quantisation error
        np.testing.assert_allclose(np.asarray(new_res[k]),
                                   np.asarray(tree[k] - deq[k]), rtol=1e-5,
                                   atol=1e-7)


def test_compressed_training_tracks_uncompressed(setup):
    """EF-int8 compressed grads must reach a similar loss (error feedback)."""
    cfg, ocfg, state, step = setup
    step_c = jax.jit(steps.make_train_step_compressed(cfg, ocfg))
    residual = comp.init_residual(state.params)
    s_a, s_b = state, state
    it_a, it_b = _batches(cfg), _batches(cfg)
    for _ in range(15):
        s_a, m_a = step(s_a, next(it_a))
        s_b, m_b, residual = step_c(s_b, next(it_b), residual)
    assert abs(float(m_a["loss"]) - float(m_b["loss"])) < 0.15 * max(
        float(m_a["loss"]), 1.0)


def test_optimizer_descends_and_clips():
    ocfg = opt_lib.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                             grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.asarray([10.0, -10.0])}
    state = opt_lib.init_state(params, ocfg)

    def loss(p):
        return (p["w"] ** 2).sum()

    for _ in range(50):
        g = jax.grad(loss)(state.params)
        state, metrics = opt_lib.apply_updates(state, g, ocfg)
    # grad-clip 1.0 bounds per-step movement to ~lr; expect steady descent
    assert float(loss(state.params)) < float(loss(params)) * 0.5
    # clipping: huge grads produce bounded update
    big = {"w": jnp.asarray([1e9, 1e9])}
    st2 = opt_lib.init_state(big, ocfg)
    g = {"w": jnp.asarray([1e9, -1e9])}
    st2b, m = opt_lib.apply_updates(st2, g, ocfg)
    assert float(jnp.abs(st2b.params["w"] - big["w"]).max()) < 1.0


def test_lr_schedule_shape():
    ocfg = opt_lib.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(opt_lib.lr_schedule(ocfg, jnp.asarray(s)))
           for s in range(0, 101, 10)]
    assert lrs[0] < lrs[1]                     # warmup rises
    assert lrs[-1] < lrs[2]                    # cosine decays
    assert lrs[-1] >= 0.099                    # floor at 10% of peak
