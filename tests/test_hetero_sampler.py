"""Heterogeneous sampling pipeline (paper C7 hetero + C9 typed-temporal)."""

import jax
import numpy as np
import pytest

from repro.core.hetero import to_hetero
from repro.data.data import HeteroData
from repro.data.hetero_sampler import HeteroNeighborLoader, \
    HeteroNeighborSampler


def _hetero_graph(rng, with_time=False):
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((50, 8)).astype(np.float32))
    hd.add_nodes("item", rng.standard_normal((80, 8)).astype(np.float32))
    ub = np.stack([rng.integers(0, 50, 300), rng.integers(0, 80, 300)])
    ii = np.stack([rng.integers(0, 80, 200), rng.integers(0, 80, 200)])
    t_ub = rng.integers(0, 100, 300) if with_time else None
    hd.add_edges(("user", "buys", "item"), ub, time=t_ub)
    hd.add_edges(("item", "rev_buys", "user"), ub[::-1], time=t_ub)
    hd.add_edges(("item", "similar", "item"), ii)
    return hd, ub, ii, t_ub


FANOUTS = {("user", "buys", "item"): [4, 2],
           ("item", "rev_buys", "user"): [3, 2],
           ("item", "similar", "item"): [3, 3]}


def test_hetero_sampled_edges_exist(rng):
    hd, ub, ii, _ = _hetero_graph(rng)
    s = HeteroNeighborSampler(hd, FANOUTS)
    out = s.sample("item", np.arange(8))
    assert out.seed_type == "item"
    for et, (src_g, dst_g) in (("user", "buys", "item"), ub), \
            (("item", "similar", "item"), ii):
        eset = set(zip(src_g.tolist(), dst_g.tolist()))
        for j in range(len(out.row[et])):
            if out.edge[et][j] < 0:
                continue
            gs = out.node[et[0]][out.row[et][j]]
            gd = out.node[et[2]][out.col[et][j]]
            assert (int(gs), int(gd)) in eset, et


def test_hetero_budgets_static(rng):
    hd, *_ = _hetero_graph(rng)
    s = HeteroNeighborSampler(hd, FANOUTS)
    a = s.sample("item", np.arange(6))
    b = s.sample("item", np.arange(40, 46))
    for t in a.node:
        assert len(a.node[t]) == len(b.node[t]), t
    for et in a.row:
        assert len(a.row[et]) == len(b.row[et]), et


def test_hetero_typed_temporal_constraint(rng):
    """Timestamped edge types respect <= t; untimestamped sample freely."""
    hd, ub, ii, t_ub = _hetero_graph(rng, with_time=True)
    s = HeteroNeighborSampler(hd, FANOUTS, temporal_strategy="recent")
    out = s.sample("item", np.arange(8), seed_time=np.full(8, 50))
    et = ("user", "buys", "item")
    eids = out.edge[et][out.edge[et] >= 0]
    assert len(eids) > 0
    assert (t_ub[eids] <= 50).all()
    # untimestamped type still samples (no constraint applied)
    et2 = ("item", "similar", "item")
    assert (out.edge[et2] >= 0).sum() > 0


def test_hetero_loader_feeds_hetero_gnn(rng):
    from repro.nn.gnn.conv import SAGEConv
    hd, *_ = _hetero_graph(rng)
    loader = HeteroNeighborLoader(
        hd, hd, num_neighbors=FANOUTS, input_type="item",
        input_nodes=np.arange(32), batch_size=8)
    metadata = (["user", "item"], list(FANOUTS))
    net = to_hetero(lambda i, o: SAGEConv(i, o), metadata, [8, 16, 4])
    params = net.init(jax.random.PRNGKey(0))
    n_batches = 0
    for batch in loader:
        res = net.apply(params, batch.x_dict, batch.edge_index_dict,
                        batch.num_nodes_dict)
        assert res["item"].shape[1] == 4
        assert np.isfinite(np.asarray(res["item"])).all()
        out_seed = batch.seed_output(res)
        assert out_seed.shape == (8, 4)
        n_batches += 1
    assert n_batches == 4
