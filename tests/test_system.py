"""End-to-end behaviour tests for the paper's system.

Each test runs a full user-facing pipeline: storage -> sampler -> loader ->
model -> jit'd training, asserting *learning* (not just shape-correctness).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.data import Data
from repro.data.loader import NeighborLoader
from repro.nn.gnn.models import make_model


def _community_graph(rng, n=600, communities=3, feat=16):
    comm = rng.integers(0, communities, n)
    src, dst = [], []
    for _ in range(n * 8):
        a, b = rng.integers(0, n), rng.integers(0, n)
        if comm[a] == comm[b] or rng.random() < 0.1:
            src.append(a), dst.append(b)
    x = rng.standard_normal((n, feat)).astype(np.float32)
    x += np.eye(communities)[comm] @ rng.standard_normal(
        (communities, feat)).astype(np.float32)
    return Data(x=x, edge_index=np.stack([np.array(src), np.array(dst)]),
                y=comm), comm


def test_minibatch_gnn_training_learns(rng):
    """Loader -> trim -> jit'd SAGE should beat chance by a wide margin."""
    data, labels = _community_graph(rng)
    n = len(labels)
    loader = NeighborLoader(data, data, num_neighbors=[6, 4], batch_size=64,
                            input_nodes=np.arange(n // 2), shuffle=True)
    model = make_model("sage", 16, 32, 3, 2)
    params = model.init(jax.random.PRNGKey(0))

    import functools

    @functools.partial(jax.jit, static_argnums=(5, 6))
    def step(params, x, ei, seeds, y, npph, epph):
        def loss_fn(p):
            out = model.apply(p, x, ei, num_sampled_nodes_per_hop=npph,
                              num_sampled_edges_per_hop=epph, trim=True)
            lp = jax.nn.log_softmax(out[seeds])
            return -jnp.take_along_axis(lp, y[:, None], 1).mean()

        l, g = jax.value_and_grad(loss_fn)(params)
        return jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, params,
                                      g), l

    first_loss, last_loss = None, None
    for epoch in range(4):
        for b in loader:
            params, loss = step(params, b.x, b.edge_index.data,
                                b.seed_slots, b.y,
                                tuple(b.num_sampled_nodes),
                                tuple(b.num_sampled_edges))
            if first_loss is None:
                first_loss = float(loss)
            last_loss = float(loss)
    assert last_loss < first_loss * 0.7, (first_loss, last_loss)

    # full-batch eval with the SAME model code (paper: seamless transition)
    from repro.core.edge_index import EdgeIndex
    src, dst, *_ = None, None
    csr = data.get_csr()
    ei = EdgeIndex.from_coo(
        np.repeat(np.arange(len(labels)), np.diff(csr.indptr)),
        csr.indices, len(labels), len(labels))
    out = model.apply(params, jnp.asarray(data.x), ei)
    test_idx = np.arange(len(labels) // 2, len(labels))
    acc = float((np.asarray(out.argmax(-1))[test_idx]
                 == labels[test_idx]).mean())
    assert acc > 0.55, acc  # chance = 1/3


def test_same_interface_minibatch_and_fullbatch(rng):
    """Identical params work on sampled and full graphs (shape-agnostic)."""
    data, labels = _community_graph(rng, n=200)
    model = make_model("gcn", 16, 16, 3, 2)
    params = model.init(jax.random.PRNGKey(0))
    loader = NeighborLoader(data, data, num_neighbors=[4, 4], batch_size=8)
    b = next(iter(loader))
    out_mb = model.apply(params, b.x, b.edge_index.data,
                         num_nodes=b.num_nodes)
    assert out_mb.shape[0] == b.num_nodes
    from repro.core.edge_index import EdgeIndex
    csr = data.get_csr()
    ei = EdgeIndex.from_coo(
        np.repeat(np.arange(200), np.diff(csr.indptr)), csr.indices, 200,
        200)
    out_fb = model.apply(params, jnp.asarray(data.x), ei)
    assert out_fb.shape == (200, 3)


def test_lm_smoke_training_learns(rng):
    """The LM path: a smoke config must fit the synthetic bigram data."""
    from repro.configs import get_config
    from repro.nn.lm import model as M
    from repro.train import data_pipeline, optimizer as opt_lib, steps
    cfg = get_config("gemma_2b", smoke=True)
    ocfg = opt_lib.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    state = opt_lib.init_state(params, ocfg)
    step = jax.jit(steps.make_train_step(cfg, ocfg), donate_argnums=(0,))
    it = data_pipeline.synthetic_batches(cfg, 4, 32, prefetch=0)
    losses = []
    for _ in range(60):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, (
        losses[:3], losses[-3:])


def test_serving_driver_end_to_end():
    """Prefill + slot-recycling batched decode produces tokens."""
    from repro.launch.serve import main
    done = main(["--arch", "qwen3-4b", "--num-requests", "4", "--batch",
                 "2", "--prompt-len", "8", "--max-new", "4"])
    assert len(done) == 4
    assert all(len(s) > 8 for s in done)
