"""First-class aggregations (paper C3): numerics + invariance properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import aggr as A


def _ref(name, vals, idx, n):
    out = np.zeros((n, vals.shape[1]), np.float32)
    for s in range(n):
        m = idx == s
        if not m.any():
            continue
        seg = vals[m]
        out[s] = {"sum": seg.sum(0), "mean": seg.mean(0),
                  "max": seg.max(0), "min": seg.min(0),
                  "var": seg.var(0)}[name]
    return out


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["sum", "mean", "max", "min", "var"]),
       st.integers(0, 2 ** 31 - 1))
def test_simple_aggr_property(name, seed):
    r = np.random.default_rng(seed)
    e, n, f = int(r.integers(1, 60)), 8, 4
    vals = r.standard_normal((e, f)).astype(np.float32)
    idx = r.integers(0, n, e).astype(np.int32)
    out = A.resolve(name).apply({}, jnp.asarray(vals), jnp.asarray(idx), n)
    np.testing.assert_allclose(np.asarray(out), _ref(name, vals, idx, n),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_permutation_invariance(seed):
    """Aggregation must be invariant to within-segment permutation."""
    r = np.random.default_rng(seed)
    e, n = 40, 6
    vals = r.standard_normal((e, 3)).astype(np.float32)
    idx = r.integers(0, n, e).astype(np.int32)
    perm = r.permutation(e)
    for name in ("sum", "mean", "max", "min", "std"):
        a = A.resolve(name).apply({}, jnp.asarray(vals), jnp.asarray(idx), n)
        b = A.resolve(name).apply({}, jnp.asarray(vals[perm]),
                                  jnp.asarray(idx[perm]), n)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_median_against_numpy(rng):
    e, n, f = 64, 7, 3
    vals = rng.standard_normal((e, f)).astype(np.float32)
    idx = np.sort(rng.integers(0, n, e)).astype(np.int32)
    ptr = np.searchsorted(idx, np.arange(n + 1)).astype(np.int32)
    out = A.MedianAggregation().apply({}, jnp.asarray(vals),
                                      jnp.asarray(idx), n,
                                      ptr=jnp.asarray(ptr))
    for s in range(n):
        m = idx == s
        if m.any():
            lower_med = np.sort(vals[m], axis=0)[(m.sum() - 1) // 2]
            np.testing.assert_allclose(np.asarray(out[s]), lower_med,
                                       rtol=1e-5)


def test_learnable_aggrs_have_grads(rng):
    e, n = 30, 5
    vals = jnp.asarray(rng.standard_normal((e, 4)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    for agg in (A.SoftmaxAggregation(), A.PowerMeanAggregation()):
        p = agg.init(jax.random.PRNGKey(0))
        g = jax.grad(lambda p: agg.apply(p, vals, idx, n).sum())(p)
        leaves = jax.tree_util.tree_leaves(g)
        assert leaves and all(np.isfinite(np.asarray(l)).all()
                              for l in leaves)
        assert any(float(np.abs(np.asarray(l)).sum()) > 0 for l in leaves)


def test_multi_aggregation_stacks(rng):
    e, n, f = 30, 5, 4
    vals = jnp.asarray(rng.standard_normal((e, f)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    multi = A.MultiAggregation([A.MeanAggregation(), A.MaxAggregation(),
                                A.StdAggregation()], mode="cat")
    out = multi.apply(multi.init(jax.random.PRNGKey(0)), vals, idx, n)
    assert out.shape == (n, 3 * f)
    mean = A.MeanAggregation().apply({}, vals, idx, n)
    np.testing.assert_allclose(np.asarray(out[:, :f]), np.asarray(mean),
                               rtol=1e-5)
